"""Link-prediction task: edge scoring with negative sampling.

A new workload on the same machinery: the graph transformer encodes the
(cluster-reordered) node sequence exactly as the node task does — elastic
ladder, dual-interleave, sharded attention all included — and the loss
scores node pairs by the scaled dot product of their final hidden states,
binary cross-entropy against sampled positives (real edges) vs negatives
(uniform random pairs).

Pair sampling is pure in ``step`` (seeded by ``(seed, step)``), so a
restart replays the exact pair stream; the pair arrays have a fixed shape
``(n_pairs,)``, so fresh samples every step never retrace. A held-out
edge set (``eval_frac``, split on *undirected* pairs so the symmetrized
reverse edge cannot leak into training) is excluded from the per-step
positive sampling and scored by ``eval(params)`` against fresh
negatives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph_model import graph_forward, with_dense_bias
from repro.tasks.node import NodeTask

F32 = jnp.float32


def link_loss(p, cfg, batch, dense: bool = False):
    """Dot-product edge scoring over the task's pair arrays:
    ``pair_src``/``pair_dst`` are sequence positions (node order already
    shifted by ``n_global``), ``pair_y`` in {0, 1}."""
    h = graph_forward(p, cfg, batch, dense)
    hn = h[0].astype(F32)                       # (S, D); link graphs are B=1
    u = jnp.take(hn, batch["pair_src"], axis=0)
    w = jnp.take(hn, batch["pair_dst"], axis=0)
    logits = (u * w).sum(-1) / np.sqrt(hn.shape[-1])
    y = batch["pair_y"].astype(F32)
    loss = jnp.mean(jax.nn.softplus(logits) - y * logits)  # BCE with logits
    acc = jnp.mean(((logits > 0) == (y > 0.5)).astype(F32))
    return loss, {"xent": loss, "acc": acc}


class LinkTask(NodeTask):
    """Edge scoring with negative sampling on a single graph.

    Reuses the node task's elastic ladder prep wholesale (the encoder
    input is identical); only the loss head and the per-step pair stream
    differ — which is the point of the Task protocol."""

    name = "link"

    def __init__(self, g, cfg, *, n_pairs: int = 256,
                 eval_frac: float = 0.1, bq: int = 32, bk: int = 32,
                 d_b: int = 8, delta: int = 10, seed: int = 0):
        super().__init__(g, cfg, bq=bq, bk=bk, d_b=d_b, delta=delta,
                         seed=seed)
        self.n_pairs = int(n_pairs)
        self.seed = seed
        ng = cfg.n_global
        inv = np.empty(g.n, np.int64)
        inv[self.prep.perm] = np.arange(g.n)
        pos_src = (inv[g.src] + ng).astype(np.int32)
        pos_dst = (inv[g.dst] + ng).astype(np.int32)
        # split on UNDIRECTED pairs: the graphs are symmetrized and the
        # dot-product score is symmetric, so holding out (u, v) while
        # training on (v, u) would leak every eval edge into training
        rng = np.random.default_rng(seed)
        lo = np.minimum(pos_src, pos_dst).astype(np.int64)
        hi = np.maximum(pos_src, pos_dst).astype(np.int64)
        key = lo * (ng + g.n + 1) + hi
        uniq, first = np.unique(key, return_index=True)
        perm_u = rng.permutation(len(uniq))
        n_eval = max(1, int(len(uniq) * eval_frac))
        held = perm_u[:n_eval]
        is_eval = np.isin(key, uniq[held])
        if is_eval.all():
            raise ValueError("eval_frac leaves no training edges")
        self._train_edges = (pos_src[~is_eval], pos_dst[~is_eval])
        # one representative direction per held-out undirected pair
        rep = first[held]
        self._eval_edges = (pos_src[rep], pos_dst[rep])
        self._node_lo, self._node_hi = ng, ng + g.n

    # ------------------------------------------------------------ data

    def _sample_pairs(self, rng, es, ed, k: int):
        """k positives from the edge list + k uniform-random negatives."""
        idx = rng.integers(0, len(es), k)
        neg_s = rng.integers(self._node_lo, self._node_hi, k)
        neg_d = rng.integers(self._node_lo, self._node_hi, k)
        src = np.concatenate([es[idx], neg_s]).astype(np.int32)
        dst = np.concatenate([ed[idx], neg_d]).astype(np.int32)
        y = np.concatenate([np.ones(k, np.int32), np.zeros(k, np.int32)])
        return src, dst, y

    def batches(self, step: int) -> dict:
        b = dict(super().batches(step))
        rng = np.random.default_rng([self.seed, step])  # pure in step
        src, dst, y = self._sample_pairs(rng, *self._train_edges,
                                         self.n_pairs // 2)
        b["pair_src"] = jnp.asarray(src)
        b["pair_dst"] = jnp.asarray(dst)
        b["pair_y"] = jnp.asarray(y)
        return b

    # ------------------------------------------------------------ losses

    @property
    def loss_variants(self):
        cfg = self.cfg
        return {
            "sparse": lambda p, b: link_loss(p, cfg, b, dense=False),
            "dense": lambda p, b: link_loss(
                p, cfg, with_dense_bias(p, cfg, b), dense=True),
        }

    # -------------------------------------------------------------- eval

    def eval(self, params) -> dict:
        """BCE/accuracy on the held-out edges vs fresh negatives."""
        rng = np.random.default_rng([self.seed + 1, 0])
        es, ed = self._eval_edges
        k = len(es)
        neg_s = rng.integers(self._node_lo, self._node_hi, k)
        neg_d = rng.integers(self._node_lo, self._node_hi, k)
        b = dict(self.batches(0))
        b["pair_src"] = jnp.asarray(np.concatenate([es, neg_s])
                                    .astype(np.int32))
        b["pair_dst"] = jnp.asarray(np.concatenate([ed, neg_d])
                                    .astype(np.int32))
        b["pair_y"] = jnp.asarray(np.concatenate(
            [np.ones(k, np.int32), np.zeros(k, np.int32)]))
        return {k_: float(v)
                for k_, v in self._metrics_fn()(params, b).items()}
