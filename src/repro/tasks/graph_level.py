"""Graph-level task: batched mini-graph classification (the paper's
MalNet/ZINC setting) with the elastic layout ladder.

Each sequence is one (small) graph; the label lives on the global token
(position 0). ``prepare_graph_task_ladder`` packs every mini-batch at
every AutoTuner rung and pads all of them to one fixed shape budget
(``pad_graph_batch``): max sequence length and max selected-k-block count
across (mini-batch x rung). Training therefore cycles ragged mini-batches
AND re-reforms the layout elastically with zero retraces — the same
two-traced-steps invariant the node task has, now for graph-level.

This is the promotion of ``examples/graph_level_training.py`` into the
real runtime: the example (and ``launch/train.py --task graph``) now
drive this task through the fault-tolerant Trainer, dense interleave and
sharded attention included.
"""

from __future__ import annotations

import numpy as np

from repro.data.graph_pipeline import (pad_graph_batch,
                                       prepare_graph_task_ladder)
from repro.tasks.elastic import ElasticTask


class GraphLevelTask(ElasticTask):
    """Batched mini-graph classification with an elastic layout.

    ``graphs`` are split into mini-batches of ``batch_graphs`` (default:
    one batch of everything); ``batches(step)`` cycles them. Pass
    ``eval_graphs`` for ``eval(params)`` to report held-out accuracy."""

    name = "graph_level"

    def __init__(self, graphs, cfg, *, eval_graphs=None,
                 batch_graphs: int | None = None, bq: int = 16,
                 bk: int = 16, d_b: int = 8, delta: int = 10,
                 seed: int = 0):
        if not graphs:
            raise ValueError("need at least one training graph")
        self.cfg = cfg
        beta_g = float(np.mean([g.sparsity for g in graphs]))
        betas = self._init_ladder(beta_g, delta)
        nb = batch_graphs or len(graphs)
        if len(graphs) % nb:
            raise ValueError(
                f"batch_graphs {nb} does not divide {len(graphs)} graphs: "
                f"the batch dim must stay constant across steps")
        splits = [graphs[i:i + nb] for i in range(0, len(graphs), nb)]
        # one ladder of preps per mini-batch, then one shape budget over
        # everything (rungs AND mini-batches): ladder moves and batch
        # cycling both swap contents only
        per_batch = [prepare_graph_task_ladder(
            gs, cfg, betas, bq=bq, bk=bk, d_b=d_b,
            with_dense_buckets=True, seed=seed) for gs in splits]
        seq_cap = max(p.layout.seq_len for ps in per_batch for p in ps)
        mb_cap = max(p.layout.mb for ps in per_batch for p in ps)
        mt_cap = max(p.layout.mt for ps in per_batch for p in ps)
        # one _shared cache per mini-batch so its rung-invariant arrays
        # stay aliased across rungs through the pad (upload-deduped)
        padded = []
        for ps in per_batch:
            shared: dict = {}
            padded.append([pad_graph_batch(p, seq_cap, mb_cap, mt_cap,
                                           _shared=shared) for p in ps])
        per_batch = padded
        self._set_rungs({bt: [ps[i] for ps in per_batch]
                         for i, bt in enumerate(betas)})
        self._eval_prep = None
        if eval_graphs:
            # held-out graphs use the paper-default layout (beta_thre=None
            # -> build_layout's 5*beta_g), independent of where the ladder
            # happens to sit — eval measures the model, not the rung
            self._eval_prep = prepare_graph_task_ladder(
                eval_graphs, cfg, [None], bq=bq, bk=bk, d_b=d_b,
                seed=seed)[0]

    # --------------------------------------------------------------- eval

    def eval(self, params) -> dict:
        """Sparse-variant metrics (graph-label accuracy) on the held-out
        graphs; {} when the task was built without ``eval_graphs``."""
        if self._eval_prep is None:
            return {}
        import jax.numpy as jnp
        b = {k: jnp.asarray(v) for k, v in self._eval_prep.batch.items()}
        return {k: float(v) for k, v in self._metrics_fn()(params, b).items()}


def synthetic_graph_level_dataset(n_graphs: int, cfg, *, seed: int = 0,
                                  n_lo: int = 60, n_hi: int = 120):
    """Synthetic classification set: each graph's class is its number of
    planted SBM clusters (1..n_classes), with a degree signal mixed into
    the features. Shared by the example, ``launch/train.py --task graph``
    and the benchmarks."""
    from repro.core.graph import sbm_graph

    rng = np.random.default_rng(seed)
    graphs = []
    for i in range(n_graphs):
        c = int(rng.integers(1, cfg.n_classes + 1))
        n = int(rng.integers(n_lo, n_hi))
        g = sbm_graph(n, c, p_in=0.25, p_out=0.01, feat_dim=cfg.feat_dim,
                      n_classes=0, seed=seed * 1000 + i, shuffle=True)
        g.labels = np.full(g.n, c - 1, np.int32)
        feat = rng.normal(0, 0.3, (g.n, cfg.feat_dim)).astype(np.float32)
        ind, _ = g.degrees()
        feat[:, 0] = ind / 20.0  # degree signal (scales with cluster size)
        g.feat = feat
        graphs.append(g)
    return graphs
