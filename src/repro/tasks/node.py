"""Node-level task: single-graph node classification (the paper's
ogbn-style workload) with the elastic layout ladder.

Absorbs the old ``runtime/elastic.ElasticGraphTask`` (which remains as an
alias): one sequence of all nodes (B=1), global tokens prepended, masked
cross-entropy over labeled positions. Loss variants come from the graph
model (``sparse`` = cluster-sparse dispatch, ``dense`` = fully-connected
interleave step biased from ``dense_buckets``).

Shape stability is the whole design (see tasks/elastic.py): every ladder
rung's layout is built once through ``prepare_node_task_ladder`` and the
``mb`` (selected-k-block) axis is padded to the max across the ladder, so
a ladder move swaps array contents only — the Trainer's two jitted steps
are traced exactly once each for the whole run.

This composes unchanged with the sharded path
(``parallel/cluster_parallel.sharded_cluster_attention``): S is constant
across rungs and whole-block (``S % bq == 0``), and the pattern operands
are replicated inside the shard_map (every device holds the full sequence
post-a2a), so the same ``block_idx``/``buckets`` drive the Ulysses
sequence-sharded attention at any rung.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.data.graph_pipeline import pad_layout_mb, prepare_node_task_ladder
from repro.tasks.elastic import ElasticTask


class NodeTask(ElasticTask):
    """Single-graph node classification with an elastic layout.

    ``train_mask`` hides non-train labels from the loss; ``eval(params)``
    then reports accuracy over the held-out (non-train) nodes, or over
    all labeled nodes when no mask was given."""

    name = "node"

    def __init__(self, g, cfg, *, train_mask=None, bq: int = 32,
                 bk: int = 32, d_b: int = 8, delta: int = 10,
                 seed: int = 0):
        self.cfg = cfg
        self.g = g
        betas = self._init_ladder(g.sparsity, delta)
        preps = dict(zip(betas, prepare_node_task_ladder(
            g, cfg, betas, bq=bq, bk=bk, d_b=d_b, train_mask=train_mask,
            with_dense_buckets=True, seed=seed)))
        seqs = {p.layout.seq_len for p in preps.values()}
        if len(seqs) != 1:  # deterministic prep => can't happen; be loud
            raise AssertionError(f"re-layout changed seq_len: {seqs}")
        mb_cap = max(p.layout.mb for p in preps.values())
        mt_cap = max(p.layout.mt for p in preps.values())
        self._set_rungs({bt: [pad_layout_mb(p, mb_cap, mt_cap)]
                         for bt, p in preps.items()})
        # held-out labels for eval: the permuted full label vector, with
        # train positions masked out when a train_mask was given
        ng = cfg.n_global
        S = next(iter(seqs))
        ev = np.full((1, S), -1, np.int32)
        if g.labels is not None:
            lab = g.labels[self.prep.perm]
            if train_mask is not None:
                lab = np.where(train_mask[self.prep.perm], -1, lab)
            ev[0, ng:ng + g.n] = lab
        self._eval_labels = ev

    # --------------------------------------------------------------- eval

    def eval(self, params) -> dict:
        """Metrics of the sparse variant on the eval label set (held-out
        nodes under a train_mask, all labeled nodes otherwise)."""
        b = dict(self.batches(0))
        b["labels"] = jnp.asarray(self._eval_labels)
        return {k: float(v) for k, v in self._metrics_fn()(params, b).items()}
