"""The Task protocol: one contract between workloads and the runtime.

TorchGT's pipeline (dual-interleaved attention, elastic reformation,
cluster-aware parallelism) is workload-agnostic in the paper — it trains
node-level and graph-level workloads through the same system. This module
is the code-side spelling of that: a ``Task`` owns everything
workload-specific and the ``Trainer`` consumes *only* this protocol —

* ``prepare(model) -> self``   bind the model handle, build layouts
                               (idempotent; constructors do the heavy prep)
* ``batches(step) -> dict``    the jnp-ready batch for an absolute step
                               (pure in ``step``: restarts replay nothing)
* ``loss_variants``            ``{"sparse": fn, ...}`` — the named losses
                               this task trains; the Trainer jits ONE step
                               per variant (the two-traced-steps invariant)
* ``variant(step, period)``    which variant this step runs (the
                               dual-interleave schedule lives here)
* ``on_epoch(loss, s, step)``  epoch-boundary signal (AutoTuner feeding)
* ``eval(params) -> metrics``  task-defined held-out evaluation
* ``state_dict`` / ``load_state_dict``  durable task state for the
                               checkpoint manifest
* ``log_extras() -> dict``     per-step scalars for the history record

Concrete tasks: ``NodeTask`` (single-graph node classification,
repro/tasks/node.py), ``GraphLevelTask`` (batched mini-graphs,
repro/tasks/graph_level.py), ``LinkTask`` (edge scoring with negative
sampling, repro/tasks/link.py), and ``BatchFnTask`` (below) wrapping any
``step -> batch`` stream (the LM families).
"""

from __future__ import annotations

from typing import Any, Callable

import jax.numpy as jnp

from repro.core.dual_attention import use_dense_step


def _model_loss_variants(model) -> dict[str, Callable]:
    """A model's named losses; duck-typed so hand-rolled test doubles that
    only expose ``.loss`` keep working."""
    lv = getattr(model, "loss_variants", None)
    return dict(lv) if lv else {"sparse": model.loss}


class Task:
    """Protocol base with shared no-op defaults: a minimal task only
    implements ``batches``. Default loss variants come from the bound
    model; the default schedule interleaves the ``"dense"`` variant (when
    the model has one) every ``period`` steps, forcing it when the C1-C3
    condition check failed — paper §III-B, now workload-generic."""

    name: str = "task"
    model: Any = None

    # ------------------------------------------------------------ binding

    def prepare(self, model) -> "Task":
        """Bind the model handle (layout prep happens in constructors and
        must be idempotent under repeated prepare calls)."""
        cfg = getattr(self, "cfg", None)
        mcfg = getattr(model, "cfg", None)
        if cfg is not None and mcfg is not None and mcfg != cfg:
            raise ValueError(
                f"task prepared for config {cfg.name!r} but the model was "
                f"built from {mcfg.name!r}")
        self.model = model
        return self

    # ------------------------------------------------------------ data

    def batches(self, step: int) -> dict:
        raise NotImplementedError

    # ------------------------------------------------------ loss/schedule

    @property
    def loss_variants(self) -> dict[str, Callable]:
        return _model_loss_variants(self.model)

    @property
    def conditions_ok(self) -> bool:
        return True

    def variant(self, step: int, interleave_period: int) -> str:
        if "dense" in self.loss_variants and use_dense_step(
                step, interleave_period, self.conditions_ok):
            return "dense"
        return "sparse"

    # ------------------------------------------------------------ elastic

    def on_epoch(self, loss: float, epoch_seconds: float,
                 step: int) -> bool:
        """Epoch-boundary feed; returns True iff the task re-laid out."""
        return False

    def log_extras(self) -> dict:
        """Extra per-step scalars recorded in ``Trainer.history``."""
        return {}

    # --------------------------------------------------------------- eval

    def eval(self, params) -> dict:
        return {}

    # ---------------------------------------------------------- durability

    def state_dict(self) -> dict:
        """Durable task state for the checkpoint manifest ({} = none)."""
        return {}

    def load_state_dict(self, d: dict) -> None:
        pass


class BatchFnTask(Task):
    """The trivial task: a seekable ``step -> host batch`` stream and the
    model's primary ("sparse") loss. This is what ``Trainer(model, cfg,
    batch_fn)`` wraps, so the LM families enter the runtime through the
    same protocol as the graph tasks."""

    name = "stream"

    def __init__(self, batch_fn: Callable[[int], dict]):
        self.batch_fn = batch_fn

    def batches(self, step: int) -> dict:
        return {k: jnp.asarray(v) for k, v in self.batch_fn(step).items()}

    @property
    def loss_variants(self) -> dict[str, Callable]:
        # streams train the primary variant only: the interleave schedule
        # belongs to tasks that own a layout to interleave against
        return {"sparse": _model_loss_variants(self.model)["sparse"]}
